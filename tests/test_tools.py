"""CI gate tools behave like gates: tools/check_bench.py fails on
regressions, on unbaselined benchmarks (--allow-new is the explicit
escape hatch) and on baseline entries missing from the run
(--allow-removed mirrors it), and tools/check_cov.py enforces the core/ line
coverage floor from a coverage.xml report.  Run as subprocesses — the
tools are argv -> exit-code programs and that interface is the contract.
tools/bench_trajectory.py (the cross-commit perf history appender) and
launch/profile_cell.py --gs-train (per-instruction attribution of the
production GS train step) are pinned the same way.
"""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def _summary(entries, mode="smoke"):
    return {"schema": 1, "mode": mode,
            "entries": [{"name": n, "config": {}, "wall_clock_s": w,
                         "result": {}} for n, w in entries]}


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def _check_bench(*args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         *args], capture_output=True, text=True, timeout=60)


def test_check_bench_passes_within_ratio(tmp_path):
    bench = _write(tmp_path, "bench.json", _summary([("a", 1.0), ("b", 2.0)]))
    base = _write(tmp_path, "base.json", _summary([("a", 1.1), ("b", 1.9)]))
    out = _check_bench("--bench", bench, "--baseline", base)
    assert out.returncode == 0, out.stdout
    assert "PASS" in out.stdout


def test_check_bench_fails_on_regression(tmp_path):
    bench = _write(tmp_path, "bench.json", _summary([("a", 10.0)]))
    base = _write(tmp_path, "base.json", _summary([("a", 1.0)]))
    out = _check_bench("--bench", bench, "--baseline", base)
    assert out.returncode == 1
    assert "REGRESSED" in out.stdout and "FAIL" in out.stdout


def test_check_bench_missing_baseline_entry_fails(tmp_path):
    """A benchmark with no baseline is an ungated benchmark — it can
    regress forever without tripping CI, so its presence must FAIL."""
    bench = _write(tmp_path, "bench.json",
                   _summary([("a", 1.0), ("new_bench", 3.0)]))
    base = _write(tmp_path, "base.json", _summary([("a", 1.0)]))
    out = _check_bench("--bench", bench, "--baseline", base)
    assert out.returncode == 1, out.stdout
    assert "no baseline for 'new_bench'" in out.stdout
    assert "FAIL" in out.stdout


def test_check_bench_allow_new_demotes_to_warning(tmp_path):
    """--allow-new is the explicit escape hatch for the PR that introduces
    a benchmark: the gate stays green, the message stays loud."""
    bench = _write(tmp_path, "bench.json",
                   _summary([("a", 1.0), ("new_bench", 3.0)]))
    base = _write(tmp_path, "base.json", _summary([("a", 1.0)]))
    out = _check_bench("--bench", bench, "--baseline", base, "--allow-new")
    assert out.returncode == 0, out.stdout
    assert "WARNING: no baseline for 'new_bench'" in out.stdout
    assert "PASS" in out.stdout
    # ...but --allow-new does NOT mask a real regression elsewhere
    bench2 = _write(tmp_path, "bench2.json",
                    _summary([("a", 9.0), ("new_bench", 3.0)]))
    out2 = _check_bench("--bench", bench2, "--baseline", base, "--allow-new")
    assert out2.returncode == 1


def test_check_bench_removed_baseline_entry_fails(tmp_path):
    """A baseline entry with no matching benchmark in the run is the same
    coverage hole from the other side — a silently dropped benchmark keeps
    the gate green while measuring less, so it must FAIL."""
    bench = _write(tmp_path, "bench.json", _summary([("a", 1.0)]))
    base = _write(tmp_path, "base.json",
                  _summary([("a", 1.0), ("old_bench", 2.0)]))
    out = _check_bench("--bench", bench, "--baseline", base)
    assert out.returncode == 1, out.stdout
    assert "baseline entry 'old_bench' missing" in out.stdout
    assert "FAIL" in out.stdout


def test_check_bench_allow_removed_demotes_to_warning(tmp_path):
    """--allow-removed is the explicit escape hatch for the PR that
    retires a benchmark (mirror of --allow-new): green gate, loud
    message, and no masking of real regressions elsewhere."""
    bench = _write(tmp_path, "bench.json", _summary([("a", 1.0)]))
    base = _write(tmp_path, "base.json",
                  _summary([("a", 1.0), ("old_bench", 2.0)]))
    out = _check_bench("--bench", bench, "--baseline", base,
                       "--allow-removed")
    assert out.returncode == 0, out.stdout
    assert "WARNING: baseline entry 'old_bench' missing" in out.stdout
    assert "PASS" in out.stdout
    # ...but --allow-removed does NOT mask a real regression elsewhere
    bench2 = _write(tmp_path, "bench2.json", _summary([("a", 9.0)]))
    out2 = _check_bench("--bench", bench2, "--baseline", base,
                        "--allow-removed")
    assert out2.returncode == 1


def test_check_bench_update_writes_baseline(tmp_path):
    bench = _write(tmp_path, "bench.json", _summary([("a", 1.0)]))
    base = str(tmp_path / "base.json")
    out = _check_bench("--bench", bench, "--baseline", base, "--update")
    assert out.returncode == 0
    assert json.load(open(base))["entries"][0]["name"] == "a"
    # the freshly updated baseline gates its own run green
    out2 = _check_bench("--bench", bench, "--baseline", base)
    assert out2.returncode == 0


COV_XML = """<?xml version="1.0" ?>
<coverage line-rate="{total}">
 <packages>
  <package name="repro.core">
   <classes>
    <class filename="src/repro/core/tiling.py" line-rate="{core}">
     <lines>{core_lines}</lines>
    </class>
    <class filename="src/repro/launch/train.py" line-rate="0.10">
     <lines><line number="1" hits="1"/><line number="2" hits="0"/></lines>
    </class>
   </classes>
  </package>
 </packages>
</coverage>
"""


def _cov_xml(tmp_path, core_hit, core_total):
    lines = "".join(
        f'<line number="{i + 1}" hits="{1 if i < core_hit else 0}"/>'
        for i in range(core_total))
    p = tmp_path / "coverage.xml"
    p.write_text(COV_XML.format(total=0.5, core=core_hit / core_total,
                                core_lines=lines))
    return str(p)


def _check_cov(*args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_cov.py"),
         *args], capture_output=True, text=True, timeout=60)


def test_check_cov_passes_above_floor(tmp_path):
    xml = _cov_xml(tmp_path, core_hit=9, core_total=10)
    out = _check_cov("--xml", xml, "--floor", "0.5")
    assert out.returncode == 0, (out.stdout, out.stderr)
    assert "PASS" in out.stdout and "90.0%" in out.stdout


def test_check_cov_fails_below_floor(tmp_path):
    xml = _cov_xml(tmp_path, core_hit=2, core_total=10)
    out = _check_cov("--xml", xml, "--floor", "0.5")
    assert out.returncode == 1, out.stdout
    assert "FAIL" in out.stdout
    # the launch/ file's 10%% line-rate must NOT have dragged the core
    # number: scoping is by filename prefix
    assert "20.0%" in out.stdout


def test_check_cov_fails_when_scope_has_no_files(tmp_path):
    xml = _cov_xml(tmp_path, core_hit=9, core_total=10)
    out = _check_cov("--xml", xml, "--floor", "0.1",
                     "--scope", "src/repro/nonexistent/")
    assert out.returncode == 1
    assert "no files" in out.stdout.lower()


# ---------------------------------------------------------------------------
# tools/bench_trajectory.py: append-only perf history
# ---------------------------------------------------------------------------


def _bench_trajectory(*args, cwd):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "bench_trajectory.py"),
         *args], capture_output=True, text=True, timeout=60, cwd=cwd)


def test_bench_trajectory_appends_and_trims(tmp_path):
    bench = _write(tmp_path, "bench.json", _summary([("a", 1.0), ("b", 2.0)]))
    traj = str(tmp_path / "traj.json")
    # first append CREATES the trajectory
    out = _bench_trajectory("--bench", bench, "--trajectory", traj,
                            "--label", "run-one", cwd=str(tmp_path))
    assert out.returncode == 0, (out.stdout, out.stderr)
    data = json.load(open(traj))
    assert data["schema"] == 1 and len(data["runs"]) == 1
    assert data["runs"][0]["meta"]["label"] == "run-one"
    assert [e["name"] for e in data["runs"][0]["entries"]] == ["a", "b"]
    # appends grow; --max-runs trims OLDEST first
    for i in range(3):
        _bench_trajectory("--bench", bench, "--trajectory", traj,
                          "--label", f"run-{i + 2}", "--max-runs", "3",
                          cwd=str(tmp_path))
    data = json.load(open(traj))
    assert [r["meta"]["label"] for r in data["runs"]] \
        == ["run-2", "run-3", "run-4"]


def test_bench_trajectory_rejects_malformed_inputs(tmp_path):
    good = _write(tmp_path, "bench.json", _summary([("a", 1.0)]))
    bad_bench = _write(tmp_path, "bad_bench.json", {"entries": []})
    out = _bench_trajectory("--bench", bad_bench, cwd=str(tmp_path))
    assert out.returncode != 0
    assert "not a schema-1 benchmark summary" in out.stderr

    bad_traj = _write(tmp_path, "bad_traj.json", {"schema": 1, "runs": "x"})
    out = _bench_trajectory("--bench", good, "--trajectory", bad_traj,
                            cwd=str(tmp_path))
    assert out.returncode != 0
    assert "not a schema-1 benchmark trajectory" in out.stderr


# ---------------------------------------------------------------------------
# launch/profile_cell.py --gs-train: attribution of the production GS step
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_profile_cell_gs_train_smoke():
    """``--gs-train`` lowers the tiered make_gs_train_step on the real
    ("part", "view") mesh and attributes its HLO — argv -> exit code 0
    with the per-device total line (the timeseries per-timestep profiling
    entry point, run here on 4 forced host devices)."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="4")
    env["PYTHONPATH"] = SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.profile_cell",
         "--gs-train", "sphere_shell", "--gs-res", "32", "--top", "5"],
        capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "gs-train-sphere_shell" in out.stdout
    assert "part,view" in out.stdout
    assert "GB per device" in out.stdout
