"""Append a benchmark run summary to the committed trajectory file.

    python tools/bench_trajectory.py --bench BENCH_SMOKE.json \
        [--trajectory BENCH_TRAJECTORY.json] [--label "..."] [--max-runs 200]

``BENCH_TRAJECTORY.json`` is the perf history the single-run gate
(tools/check_bench.py) cannot give: one appended record per bench-job run
— ``{"schema": 1, "runs": [{"mode", "meta", "entries"}, ...]}`` — where
``entries`` is the run's schema-1 summary (benchmarks/run.py --json) and
``meta`` records provenance (git sha / CI run id from the GITHUB_* env
when present, plus an optional --label).  The committed file is the base
history; CI appends its fresh run and uploads the grown file as an
artifact, so slow drifts that stay under the 2x single-run gate are still
visible across commits.  Oldest runs are trimmed past --max-runs.

Exit code is the contract (tests/test_tools.py style): 0 on append,
nonzero on a malformed summary or trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _load_summary(path):
    with open(path) as f:
        data = json.load(f)
    if data.get("schema") != 1 or "entries" not in data:
        raise SystemExit(f"{path}: not a schema-1 benchmark summary")
    return data


def _load_trajectory(path):
    if not os.path.exists(path):
        return {"schema": 1, "runs": []}
    with open(path) as f:
        data = json.load(f)
    if data.get("schema") != 1 or not isinstance(data.get("runs"), list):
        raise SystemExit(f"{path}: not a schema-1 benchmark trajectory "
                         f"(expected {{'schema': 1, 'runs': [...]}})")
    return data


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_SMOKE.json",
                    help="summary produced by benchmarks.run --json")
    ap.add_argument("--trajectory", default="BENCH_TRAJECTORY.json",
                    help="trajectory file to append to (created if missing)")
    ap.add_argument("--label", default="",
                    help="free-form provenance note for this run")
    ap.add_argument("--max-runs", type=int, default=200,
                    help="keep only the newest N runs")
    args = ap.parse_args(argv)

    bench = _load_summary(args.bench)
    traj = _load_trajectory(args.trajectory)
    meta = {k: os.environ[e] for k, e in
            (("sha", "GITHUB_SHA"), ("run_id", "GITHUB_RUN_ID"),
             ("ref", "GITHUB_REF_NAME")) if os.environ.get(e)}
    if args.label:
        meta["label"] = args.label
    traj["runs"].append({"mode": bench.get("mode"), "meta": meta,
                         "entries": bench["entries"]})
    if args.max_runs > 0:
        traj["runs"] = traj["runs"][-args.max_runs:]
    with open(args.trajectory, "w") as f:
        json.dump(traj, f, indent=1)
        f.write("\n")
    names = [e["name"] for e in bench["entries"]]
    print(f"[bench_trajectory] appended run #{len(traj['runs'])} "
          f"({len(names)} entries: {', '.join(names)}) -> "
          f"{args.trajectory}")


if __name__ == "__main__":
    sys.exit(main())
