"""CI benchmark-regression gate: compare a benchmark summary against the
committed baseline and fail on wall-clock regressions.

    # produce a summary (CI does this in the bench job)
    PYTHONPATH=src python -m benchmarks.run --smoke --json BENCH_SMOKE.json

    # gate: exit 1 if any benchmark regressed past --max-ratio (default 2x)
    python tools/check_bench.py --bench BENCH_SMOKE.json

    # refresh the committed baseline after an intentional perf change
    python tools/check_bench.py --bench BENCH_SMOKE.json --update

The baseline (benchmarks/baseline.json) and the per-run summaries
(BENCH_*.json) share one schema — ``{"schema": 1, "mode": ...,
"entries": [{"name", "config", "wall_clock_s"}, ...]}`` — emitted by
``benchmarks/run.py --json``.  The 2x default ratio absorbs shared-runner
noise (absolute wall-clocks are machine-dependent) while still catching
step-change regressions like an accidentally recompiling hot loop; refresh
the baseline with --update when a PR intentionally shifts the numbers.
Because the comparison is on ABSOLUTE wall-clocks, the committed baseline
should come from the same machine class as the gate: after the bench job's
first green run, download its bench-smoke artifact and commit
`check_bench.py --bench BENCH_SMOKE.json --update`'s output so baseline
and measurement share runner hardware (a dev-box baseline on a runner that
is legitimately >2x slower reads as a regression).  The two files must
also share the run MODE (smoke vs default/full) — mismatches fail loudly.

Benchmarks present in the run but missing from the baseline FAIL the gate:
an ungated benchmark is a silent coverage hole (it can regress forever
without tripping CI).  The escape hatch for the PR that introduces a new
benchmark is ``--allow-new`` — CI stays green while the run's artifact is
used to commit an --update'd baseline alongside the new benchmark.
Baseline entries missing from the run fail symmetrically: a silently
dropped benchmark is the SAME coverage hole from the other side (the gate
would keep reporting green while measuring less and less).  The escape
hatch for the PR that deliberately retires a benchmark is
``--allow-removed`` — pass it once, and commit an --update'd baseline
without the retired entry.
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        data = json.load(f)
    if data.get("schema") != 1 or "entries" not in data:
        raise SystemExit(
            f"{path}: not a schema-1 benchmark summary (run `python -m "
            f"benchmarks.run --smoke --json {path}` — match the baseline's "
            "mode)")
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_SMOKE.json",
                    help="summary produced by benchmarks.run --json")
    ap.add_argument("--baseline", default="benchmarks/baseline.json",
                    help="committed reference summary")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when wall_clock_s exceeds baseline * ratio")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from --bench and exit 0")
    ap.add_argument("--allow-new", action="store_true",
                    help="demote missing-baseline entries from FAIL to "
                         "WARNING (the escape hatch for the PR that adds "
                         "a benchmark; commit an --update'd baseline)")
    ap.add_argument("--allow-removed", action="store_true",
                    help="demote baseline entries missing from the run "
                         "from FAIL to WARNING (the escape hatch for the "
                         "PR that retires a benchmark; commit an "
                         "--update'd baseline)")
    args = ap.parse_args()

    bench = load(args.bench)
    if args.update:
        with open(args.baseline, "w") as f:
            json.dump(bench, f, indent=1)
            f.write("\n")
        print(f"[check_bench] baseline updated: {args.baseline} "
              f"({len(bench['entries'])} entries)")
        return

    base = load(args.baseline)
    if bench.get("mode") != base.get("mode"):
        raise SystemExit(
            f"[check_bench] FAIL: mode mismatch — {args.bench} was run in "
            f"{bench.get('mode')!r} mode but {args.baseline} holds "
            f"{base.get('mode')!r} wall-clocks; comparing them would make "
            "the ratio gate meaningless.  Re-run the benchmarks in the "
            "baseline's mode, or refresh the baseline with --update.")
    base_by_name = {e["name"]: e for e in base["entries"]}
    failures, unbaselined = [], []
    for e in bench["entries"]:
        ref = base_by_name.pop(e["name"], None)
        if ref is None:
            sev = "WARNING" if args.allow_new else "FAIL"
            print(f"[check_bench] {sev}: no baseline for "
                  f"{e['name']!r} ({e['wall_clock_s']:.1f}s) — new "
                  "benchmark?  Refresh with --update"
                  + ("." if args.allow_new
                     else " (or pass --allow-new on the PR adding it)."))
            if not args.allow_new:
                unbaselined.append(e["name"])
            continue
        ratio = e["wall_clock_s"] / max(ref["wall_clock_s"], 1e-9)
        status = "OK" if ratio <= args.max_ratio else "REGRESSED"
        print(f"[check_bench] {e['name']:20s} {e['wall_clock_s']:8.1f}s  "
              f"baseline {ref['wall_clock_s']:8.1f}s  ({ratio:.2f}x)  "
              f"{status}")
        if ratio > args.max_ratio:
            failures.append((e["name"], ratio))
    removed = []
    for name in base_by_name:
        sev = "WARNING" if args.allow_removed else "FAIL"
        print(f"[check_bench] {sev}: baseline entry {name!r} missing from "
              "this run — removed benchmark?  Refresh with --update"
              + ("." if args.allow_removed
                 else " (or pass --allow-removed on the PR retiring it)."))
        if not args.allow_removed:
            removed.append(name)
    bad = False
    if failures:
        names = ", ".join(f"{n} ({r:.2f}x)" for n, r in failures)
        print("[check_bench] FAIL: wall-clock regression past "
              f"{args.max_ratio}x vs {args.baseline}: {names}")
        bad = True
    if unbaselined:
        print("[check_bench] FAIL: unbaselined benchmark(s) "
              f"{', '.join(repr(n) for n in unbaselined)} — refresh "
              f"{args.baseline} with --update (or pass --allow-new on the "
              "PR adding them)")
        bad = True
    if removed:
        print("[check_bench] FAIL: baseline benchmark(s) "
              f"{', '.join(repr(n) for n in removed)} missing from this "
              f"run — refresh {args.baseline} with --update (or pass "
              "--allow-removed on the PR retiring them)")
        bad = True
    if bad:
        sys.exit(1)
    print(f"[check_bench] PASS: {len(bench['entries'])} benchmark(s) "
          f"within {args.max_ratio}x of baseline")


if __name__ == "__main__":
    main()
