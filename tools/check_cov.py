"""CI coverage floor: parse a Cobertura-style ``coverage.xml`` (as written
by ``pytest --cov=repro --cov-report=xml``) and fail when line coverage of
the scoped files drops below the floor.

    PYTHONPATH=src python -m pytest -q --cov=repro --cov-report=xml
    python tools/check_cov.py --xml coverage.xml --floor 0.45

Scoping is by filename prefix (default ``src/repro/core/``): the floor
gates the numeric core — projection, tiling, raster, train, distributed —
not the whole tree, so launcher/tooling churn can't dilute the number and
an untested core can't hide behind well-covered glue.  Coverage is
recomputed from the per-line ``hits`` attributes rather than trusting the
report's ``line-rate`` aggregates, so partial/merged reports stay honest.

An empty scope (no files match the prefix) is a FAIL, not a trivial pass:
it means the report was produced without the code under gate (wrong
--cov target, wrong working directory), which is exactly the silent
failure mode this gate exists to catch.
"""

from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET

DEFAULT_SCOPE = "src/repro/core/"


def _norm(path):
    """Drop a leading ``src/`` so scope matching is stable whether the
    report's filenames are repo-relative (``src/repro/...``) or source-root
    relative (``repro/...`` with ``src`` in Cobertura's <sources>)."""
    return path[4:] if path.startswith("src/") else path


def scoped_line_counts(xml_path, scope):
    """Return (covered, total, n_files) over <class> elements whose
    filename starts with ``scope``, counting <line hits=...> entries."""
    root = ET.parse(xml_path).getroot()
    scope = _norm(scope)
    covered = total = n_files = 0
    for cls in root.iter("class"):
        fname = _norm(cls.get("filename", ""))
        if not fname.startswith(scope):
            continue
        n_files += 1
        for line in cls.iter("line"):
            total += 1
            if int(line.get("hits", "0")) > 0:
                covered += 1
    return covered, total, n_files


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--xml", default="coverage.xml",
                    help="Cobertura XML report from --cov-report=xml")
    ap.add_argument("--floor", type=float, required=True,
                    help="minimum line-coverage fraction, e.g. 0.45")
    ap.add_argument("--scope", default=DEFAULT_SCOPE,
                    help="filename prefix to gate (default: the core/)")
    args = ap.parse_args()

    covered, total, n_files = scoped_line_counts(args.xml, args.scope)
    if n_files == 0 or total == 0:
        print(f"[check_cov] FAIL: no files under scope {args.scope!r} in "
              f"{args.xml} — wrong --cov target or working directory?")
        sys.exit(1)
    rate = covered / total
    status = "PASS" if rate >= args.floor else "FAIL"
    print(f"[check_cov] {status}: {args.scope} line coverage "
          f"{100.0 * rate:.1f}% ({covered}/{total} lines, {n_files} "
          f"files; floor {100.0 * args.floor:.1f}%)")
    if rate < args.floor:
        sys.exit(1)


if __name__ == "__main__":
    main()
