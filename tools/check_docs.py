"""Docs gate: keep README/docs honest.

1. Intra-repo link check: every relative markdown link in README.md and
   docs/**/*.md must resolve to an existing file (anchors are stripped;
   http(s)/mailto links are skipped).
2. Code-block execution: every ```python fenced block in README.md is
   executed (in its own namespace, cwd = repo root, src/ on sys.path).  A
   quickstart snippet that drifts from the API fails the build.

    PYTHONPATH=src python tools/check_docs.py

Exit code 0 = docs are runnable and link-clean.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def doc_files():
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors = []
    for md in doc_files():
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            # GitHub resolves /-leading links against the repo root
            base = ROOT if path.startswith("/") else md.parent
            resolved = (base / path.lstrip("/")).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> "
                              f"{target}")
    return errors


def run_code_blocks() -> list[str]:
    errors = []
    sys.path.insert(0, str(ROOT / "src"))
    readme = ROOT / "README.md"
    blocks = FENCE_RE.findall(readme.read_text())
    if not blocks:
        errors.append("README.md: no ```python blocks found (the quickstart "
                      "snippet is part of the docs contract)")
    for i, block in enumerate(blocks):
        print(f"[check_docs] executing README.md python block {i + 1}/"
              f"{len(blocks)} ({len(block.splitlines())} lines)")
        try:
            exec(compile(block, f"README.md#block{i + 1}", "exec"), {})
        except Exception as e:  # pragma: no cover - the gate itself
            errors.append(f"README.md python block {i + 1} raised "
                          f"{type(e).__name__}: {e}")
    return errors


def main() -> int:
    errors = check_links()
    print(f"[check_docs] link check: {len(doc_files())} files, "
          f"{len(errors)} broken")
    errors += run_code_blocks()
    for e in errors:
        print(f"[check_docs] FAIL: {e}")
    if errors:
        return 1
    print("[check_docs] ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
