"""Docs gate: keep README/docs honest.

1. Intra-repo link check: every relative markdown link in README.md and
   docs/**/*.md must resolve to an existing file (anchors are stripped;
   http(s)/mailto links are skipped).
2. Code-block execution: every ```python fenced block in README.md AND
   docs/**/*.md is executed.  Each file's blocks are concatenated in order
   and run in ONE fresh subprocess (cwd = repo root, src/ on sys.path), so
   later blocks may build on earlier ones, and a block may set env vars
   (e.g. XLA_FLAGS for a host-device mesh) before importing jax — the
   distributed-training guide relies on this.  A snippet that drifts from
   the API fails the build.

    PYTHONPATH=src python tools/check_docs.py

Exit code 0 = docs are runnable and link-clean.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)

#: per-file wall-clock budget for snippet execution; the distributed guide
#: compiles a 4-device shard_map program on CPU, which dominates
BLOCK_TIMEOUT_S = 900


def doc_files():
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors = []
    for md in doc_files():
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            # GitHub resolves /-leading links against the repo root
            base = ROOT if path.startswith("/") else md.parent
            resolved = (base / path.lstrip("/")).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> "
                              f"{target}")
    return errors


def run_code_blocks() -> list[str]:
    errors = []
    any_blocks = False
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for md in doc_files():
        rel = md.relative_to(ROOT)
        blocks = FENCE_RE.findall(md.read_text())
        if not blocks:
            continue
        any_blocks = True
        # one subprocess per FILE: blocks run in order and share state, and
        # env tweaks in an early block (XLA_FLAGS) apply to later imports
        script = "\n\n".join(
            f"# --- {rel} block {i + 1}/{len(blocks)}\n"
            f"print('[check_docs] {rel} block {i + 1}/{len(blocks)}', "
            f"flush=True)\n{b}"
            for i, b in enumerate(blocks))
        print(f"[check_docs] executing {rel}: {len(blocks)} python block(s), "
              f"{len(script.splitlines())} lines")
        try:
            proc = subprocess.run([sys.executable, "-c", script],
                                  cwd=ROOT, env=env, capture_output=True,
                                  text=True, timeout=BLOCK_TIMEOUT_S)
        except subprocess.TimeoutExpired:
            errors.append(f"{rel}: python blocks exceeded "
                          f"{BLOCK_TIMEOUT_S}s")
            continue
        sys.stdout.write(proc.stdout)
        if proc.returncode != 0:
            errors.append(f"{rel}: python blocks failed (exit "
                          f"{proc.returncode}):\n{proc.stderr[-2000:]}")
    if not any_blocks:
        errors.append("no ```python blocks found in README.md/docs (the "
                      "quickstart snippets are part of the docs contract)")
    readme_blocks = FENCE_RE.findall((ROOT / "README.md").read_text())
    if not readme_blocks:
        errors.append("README.md: no ```python blocks found (the quickstart "
                      "snippet is part of the docs contract)")
    return errors


def main() -> int:
    errors = check_links()
    print(f"[check_docs] link check: {len(doc_files())} files, "
          f"{len(errors)} broken")
    errors += run_code_blocks()
    for e in errors:
        print(f"[check_docs] FAIL: {e}")
    if errors:
        return 1
    print("[check_docs] ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
